"""Operation metering for storage engines.

Every engine API call records an :class:`Op` describing *who* (client
process), *where* (server-side resource: DAOS target, Ceph OSD, Lustre
OST/MDS), *what* (op kind), and *how much* (payload bytes).  The trace feeds
the analytic cost model (:mod:`.costmodel`) that converts in-process runs into
modeled at-scale cluster bandwidth — the hardware-gate simulation strategy
described in DESIGN.md §3.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
from collections import Counter
from typing import Dict, Iterator, List, Optional

_client_var: contextvars.ContextVar[str] = contextvars.ContextVar(
    "fdbx_client", default="proc0@node0")


def current_client() -> str:
    return _client_var.get()


@contextlib.contextmanager
def client_context(client: str) -> Iterator[None]:
    """Tag engine ops issued in this context as coming from ``client``.

    Client ids follow ``procN@nodeM`` so the cost model can aggregate
    per-node network usage.
    """
    tok = _client_var.set(client)
    try:
        yield
    finally:
        _client_var.reset(tok)


@dataclasses.dataclass(frozen=True)
class Op:
    client: str      # "proc3@node1"
    resource: str    # "target:5" | "osd:2" | "ost:7" | "mds" | "mon" | "s3"
    kind: str        # kv_put|kv_get|kv_list|array_write|array_read|meta|lock|
                     # fsync|append|write|read|omap_set|omap_get|http_put|...
    nbytes: int
    unit: str = ""   # hot-spot unit (e.g. a KV object key) for contention model


#: default cap on the retained op trace (~1M ops); rollup counters stay
#: exact past the cap, only the per-op list stops growing
DEFAULT_MAX_OPS = 1 << 20


class Meter:
    """Thread-safe op trace + rollup counters.

    The per-op trace (``ops``) is bounded by ``max_ops`` (None = unbounded):
    past the cap the meter switches to rollup-only mode — :meth:`record`
    keeps updating the exact incremental counters that :meth:`summary`
    reports, but drops the :class:`Op` object instead of appending it.
    Nothing is evicted, so ``snapshot()`` stays a stable prefix of the run
    and existing ``snapshot()[len(before):]`` windowing keeps working below
    the cap.  Truncation is visible via ``dropped_ops`` and the
    ``trace_truncated`` summary field.
    """

    def __init__(self, max_ops: Optional[int] = DEFAULT_MAX_OPS) -> None:
        self._lock = threading.Lock()
        self.ops: List[Op] = []
        self.enabled = True
        self.max_ops = max_ops
        self._dropped = 0
        # exact rollups, maintained incrementally so they survive truncation
        self._kind_count: Counter = Counter()
        self._kind_bytes: Counter = Counter()
        self._clients: set = set()
        self._resources: set = set()
        self._total = 0

    def record(self, resource: str, kind: str, nbytes: int = 0,
               unit: str = "") -> None:
        if not self.enabled:
            return
        op = Op(current_client(), resource, kind, nbytes, unit)
        with self._lock:
            self._total += 1
            self._kind_count[kind] += 1
            self._kind_bytes[kind] += nbytes
            self._clients.add(op.client)
            self._resources.add(resource)
            if self.max_ops is None or len(self.ops) < self.max_ops:
                self.ops.append(op)
            else:
                self._dropped += 1

    def reset(self) -> None:
        with self._lock:
            self.ops = []
            self._dropped = 0
            self._kind_count = Counter()
            self._kind_bytes = Counter()
            self._clients = set()
            self._resources = set()
            self._total = 0

    @property
    def dropped_ops(self) -> int:
        """Ops counted in rollups but not retained in the trace."""
        return self._dropped

    # Rollups ----------------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        with self._lock:
            out = {
                "total_ops": self._total,
                "ops_by_kind": dict(self._kind_count),
                "bytes_by_kind": dict(self._kind_bytes),
                "clients": len(self._clients),
                "resources": len(self._resources),
            }
            if self._dropped:
                out["dropped_ops"] = self._dropped
                out["trace_truncated"] = True
            return out

    def snapshot(self) -> List[Op]:
        with self._lock:
            return list(self.ops)


#: A process-global default meter — backends use it unless given their own.
GLOBAL_METER = Meter()

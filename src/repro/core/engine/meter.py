"""Operation metering for storage engines.

Every engine API call records an :class:`Op` describing *who* (client
process), *where* (server-side resource: DAOS target, Ceph OSD, Lustre
OST/MDS), *what* (op kind), and *how much* (payload bytes).  The trace feeds
the analytic cost model (:mod:`.costmodel`) that converts in-process runs into
modeled at-scale cluster bandwidth — the hardware-gate simulation strategy
described in DESIGN.md §3.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
from collections import Counter
from typing import Dict, Iterator, List, Optional

_client_var: contextvars.ContextVar[str] = contextvars.ContextVar(
    "fdbx_client", default="proc0@node0")


def current_client() -> str:
    return _client_var.get()


@contextlib.contextmanager
def client_context(client: str) -> Iterator[None]:
    """Tag engine ops issued in this context as coming from ``client``.

    Client ids follow ``procN@nodeM`` so the cost model can aggregate
    per-node network usage.
    """
    tok = _client_var.set(client)
    try:
        yield
    finally:
        _client_var.reset(tok)


@dataclasses.dataclass(frozen=True)
class Op:
    client: str      # "proc3@node1"
    resource: str    # "target:5" | "osd:2" | "ost:7" | "mds" | "mon" | "s3"
    kind: str        # kv_put|kv_get|kv_list|array_write|array_read|meta|lock|
                     # fsync|append|write|read|omap_set|omap_get|http_put|...
    nbytes: int
    unit: str = ""   # hot-spot unit (e.g. a KV object key) for contention model


class Meter:
    """Thread-safe op trace + rollup counters."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.ops: List[Op] = []
        self.enabled = True

    def record(self, resource: str, kind: str, nbytes: int = 0,
               unit: str = "") -> None:
        if not self.enabled:
            return
        op = Op(current_client(), resource, kind, nbytes, unit)
        with self._lock:
            self.ops.append(op)

    def reset(self) -> None:
        with self._lock:
            self.ops = []

    # Rollups ----------------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        with self._lock:
            ops = list(self.ops)
        kinds = Counter(op.kind for op in ops)
        bytes_by_kind: Counter = Counter()
        for op in ops:
            bytes_by_kind[op.kind] += op.nbytes
        return {
            "total_ops": len(ops),
            "ops_by_kind": dict(kinds),
            "bytes_by_kind": dict(bytes_by_kind),
            "clients": len({op.client for op in ops}),
            "resources": len({op.resource for op in ops}),
        }

    def snapshot(self) -> List[Op]:
        with self._lock:
            return list(self.ops)


#: A process-global default meter — backends use it unless given their own.
GLOBAL_METER = Meter()

"""In-process S3-like engine (thesis §3.3).

REST-over-HTTP object semantics: buckets, PUT-replaces-whole-object,
GET with optional byte range, listing with prefix, and multipart uploads
(drafted in the thesis; implemented here).  No atomic append, no KV objects —
which is exactly why no conforming S3 Catalogue exists (§3.3).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

from .meter import GLOBAL_METER, Meter


class S3ApiError(RuntimeError):
    pass


class S3Engine:
    def __init__(self, meter: Optional[Meter] = None):
        self.meter = meter or GLOBAL_METER
        self.buckets: Dict[str, Dict[str, bytes]] = {}
        self._mpu: Dict[str, Tuple[str, str, Dict[int, bytes]]] = {}
        self._mpu_seq = 0
        self._lock = threading.Lock()

    def create_bucket(self, bucket: str) -> None:
        with self._lock:
            self.buckets.setdefault(bucket, {})
        self.meter.record("s3", "meta", 0)

    def delete_bucket(self, bucket: str) -> None:
        with self._lock:
            self.buckets.pop(bucket, None)
        self.meter.record("s3", "meta", 0)

    def _bucket(self, bucket: str) -> Dict[str, bytes]:
        b = self.buckets.get(bucket)
        if b is None:
            raise S3ApiError(f"NoSuchBucket: {bucket}")
        return b

    def put_object(self, bucket: str, key: str, data: bytes) -> None:
        """PUT: fully written or failed; last racing PUT prevails (§3.3)."""
        b = self._bucket(bucket)
        b[key] = bytes(data)                 # atomic publish
        self.meter.record("s3", "http_put", len(data))

    def get_object(self, bucket: str, key: str,
                   byte_range: Optional[Tuple[int, int]] = None) -> bytes:
        b = self._bucket(bucket)
        if key not in b:
            self.meter.record("s3", "http_get", 0)
            raise S3ApiError(f"NoSuchKey: {key}")
        data = b[key]
        if byte_range is not None:
            lo, hi = byte_range
            data = data[lo:hi + 1]           # HTTP Range is inclusive
        self.meter.record("s3", "http_get", len(data))
        return data

    def head_object(self, bucket: str, key: str) -> Optional[int]:
        b = self._bucket(bucket)
        self.meter.record("s3", "meta", 0)
        return len(b[key]) if key in b else None

    def delete_object(self, bucket: str, key: str) -> None:
        b = self._bucket(bucket)
        b.pop(key, None)
        self.meter.record("s3", "meta", 0)

    def list_objects(self, bucket: str, prefix: str = "") -> List[str]:
        b = self._bucket(bucket)
        keys = sorted(k for k in b if k.startswith(prefix))
        self.meter.record("s3", "http_list", sum(len(k) for k in keys))
        return keys

    # -- multipart uploads ------------------------------------------------------
    def create_multipart_upload(self, bucket: str, key: str) -> str:
        self._bucket(bucket)
        with self._lock:
            self._mpu_seq += 1
            upload_id = f"mpu-{self._mpu_seq}"
            self._mpu[upload_id] = (bucket, key, {})
        self.meter.record("s3", "meta", 0)
        return upload_id

    def upload_part(self, upload_id: str, part_number: int,
                    data: bytes) -> int:
        if upload_id not in self._mpu:
            raise S3ApiError(f"NoSuchUpload: {upload_id}")
        self._mpu[upload_id][2][part_number] = bytes(data)
        self.meter.record("s3", "http_put", len(data))
        return part_number

    def complete_multipart_upload(self, upload_id: str) -> None:
        with self._lock:
            entry = self._mpu.pop(upload_id, None)
        if entry is None:
            raise S3ApiError(f"NoSuchUpload: {upload_id}")
        bucket, key, parts = entry
        blob = b"".join(parts[i] for i in sorted(parts))
        self._bucket(bucket)[key] = blob     # assembled object published
        self.meter.record("s3", "meta", 0)

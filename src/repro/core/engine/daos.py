"""In-process DAOS-like object engine (thesis §2.3).

Implements the libdaos surface the FDB DAOS backends need — pools,
containers, OID allocation, high-level key-value and array objects — with the
semantics that matter:

* **Immediate persistence**: every put/write is durable-and-visible on return.
* **MVCC, no client locks**: writes create a new version; readers always see
  the latest *complete* version; writers never block readers.
* **Algorithmic placement**: target = stable_hash(oid) % n_targets; no
  centralized metadata servers.
* **Object classes**: OC_S1 (one target), OC_S2/OC_SX (striped), OC_RP_2G1
  (2-way replication), OC_EC_2P1G1 (2+1 erasure coding).  Redundancy is
  modeled by metering replica/parity traffic to secondary targets.
* **OID batching**: ``cont_alloc_oids`` reserves ranges in one RPC (§3.1.1).

Every API call meters an :class:`..meter.Op` for the cost model.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

from .meter import GLOBAL_METER, Meter
from ..util import stable_hash

MiB = 1024 ** 2

OBJECT_CLASSES = ("OC_S1", "OC_S2", "OC_S4", "OC_SX", "OC_RP_2G1",
                  "OC_RP_3G1", "OC_EC_2P1G1")


class DaosApiError(RuntimeError):
    pass


@dataclasses.dataclass
class _KVEntry:
    version: int
    value: bytes


class _KVObject:
    """A DAOS high-level key-value object with MVCC puts."""

    __slots__ = ("entries", "oclass", "_version")

    def __init__(self, oclass: str = "OC_S1"):
        self.entries: Dict[str, _KVEntry] = {}
        self.oclass = oclass
        self._version = 0

    def put(self, key: str, value: bytes) -> None:
        # MVCC: build the new immutable entry first, then publish atomically
        # (single dict slot assignment — readers see old or new, never partial).
        self._version += 1
        self.entries[key] = _KVEntry(self._version, bytes(value))

    def get(self, key: str) -> Optional[bytes]:
        e = self.entries.get(key)
        return None if e is None else e.value

    def keys(self) -> List[str]:
        return list(self.entries.keys())


class _ArrayObject:
    """A DAOS array object: byte-addressable 1-D array.

    Visibility follows DAOS semantics: a write's extent becomes readable once
    the write returns (we publish the committed size last).
    """

    __slots__ = ("chunks", "committed_size", "oclass")

    def __init__(self, oclass: str = "OC_S1"):
        self.chunks: Dict[int, bytes] = {}      # offset -> bytes
        self.committed_size = 0
        self.oclass = oclass

    def write(self, offset: int, data: bytes) -> None:
        self.chunks[offset] = bytes(data)
        new_end = offset + len(data)
        if new_end > self.committed_size:
            self.committed_size = new_end       # publish last (atomic int set)

    def read(self, offset: int, length: int) -> bytes:
        end = min(offset + length, self.committed_size)
        if end <= offset:
            return b""
        buf = bytearray(end - offset)
        for coff, cdata in self.chunks.items():
            lo = max(offset, coff)
            hi = min(end, coff + len(cdata))
            if lo < hi:
                buf[lo - offset:hi - offset] = cdata[lo - coff:hi - coff]
        return bytes(buf)

    def size(self) -> int:
        return self.committed_size


class _Container:
    def __init__(self, label: str):
        self.label = label
        self.kvs: Dict[int, _KVObject] = {}
        self.arrays: Dict[int, _ArrayObject] = {}
        self.next_oid = 1
        self.lock = threading.Lock()


class _Pool:
    def __init__(self, name: str):
        self.name = name
        self.containers: Dict[str, _Container] = {}
        self.lock = threading.Lock()


class DaosEngine:
    """Engine state shared by all clients of one simulated DAOS system."""

    def __init__(self, n_targets: int = 16, meter: Optional[Meter] = None):
        self.n_targets = n_targets
        self.meter = meter or GLOBAL_METER
        self.pools: Dict[str, _Pool] = {}
        self._lock = threading.Lock()

    # -- placement -----------------------------------------------------------
    def _target(self, oid: int, shard: int = 0) -> str:
        return f"target:{(stable_hash(str(oid)) + shard) % self.n_targets}"

    def _stripes(self, oclass: str) -> int:
        if oclass == "OC_S2":
            return 2
        if oclass == "OC_S4":
            return 4
        if oclass == "OC_SX":
            return self.n_targets
        return 1

    def _replicas(self, oclass: str) -> Tuple[int, float]:
        """(extra full replicas, parity fraction) for redundancy classes."""
        if oclass == "OC_RP_2G1":
            return 1, 0.0
        if oclass == "OC_RP_3G1":
            return 2, 0.0
        if oclass == "OC_EC_2P1G1":
            return 0, 0.5            # 2 data + 1 parity cells
        return 0, 0.0

    # -- pool / container management ------------------------------------------
    def pool_create(self, name: str) -> None:
        with self._lock:
            self.pools.setdefault(name, _Pool(name))

    def pool_connect(self, name: str) -> str:
        self.meter.record("target:0", "meta", 0, unit=f"pool:{name}")
        if name not in self.pools:
            raise DaosApiError(f"no such pool {name!r}")
        return name

    def cont_create_with_label(self, pool: str, label: str) -> None:
        """Atomic create-if-absent (daos_cont_create_with_label, §3.1.1)."""
        p = self.pools[pool]
        with p.lock:
            if label not in p.containers:
                p.containers[label] = _Container(label)
        self.meter.record("target:0", "meta", 0, unit=f"cont:{label}")

    def cont_open(self, pool: str, label: str) -> _Container:
        p = self.pools[pool]
        c = p.containers.get(label)
        if c is None:
            raise DaosApiError(f"no such container {label!r} in pool {pool!r}")
        self.meter.record("target:0", "meta", 0, unit=f"cont:{label}")
        return c

    def cont_destroy(self, pool: str, label: str) -> None:
        p = self.pools[pool]
        with p.lock:
            p.containers.pop(label, None)
        self.meter.record("target:0", "meta", 0)

    def cont_list(self, pool: str) -> List[str]:
        self.meter.record("target:0", "meta", 0)
        return list(self.pools[pool].containers.keys())

    def cont_alloc_oids(self, pool: str, label: str, count: int) -> int:
        """Reserve ``count`` OIDs; returns the first.  One RPC per batch."""
        c = self.cont_open(pool, label)
        with c.lock:
            first = c.next_oid
            c.next_oid += count
        self.meter.record("target:0", "oid_alloc", 0)
        return first

    # -- key-value API ---------------------------------------------------------
    def _kv(self, pool: str, label: str, oid: int, create: bool = True
            ) -> _KVObject:
        c = self.pools[pool].containers[label]
        kv = c.kvs.get(oid)
        if kv is None:
            if not create:
                raise DaosApiError(f"kv {oid} absent")
            with c.lock:
                kv = c.kvs.setdefault(oid, _KVObject())
        return kv

    def kv_put(self, pool: str, label: str, oid: int, key: str,
               value: bytes) -> None:
        kv = self._kv(pool, label, oid)
        kv.put(key, value)
        self.meter.record(self._target(oid), "kv_put", len(value),
                          unit=f"{label}/kv{oid}")

    def kv_get(self, pool: str, label: str, oid: int, key: str
               ) -> Optional[bytes]:
        c = self.pools[pool].containers.get(label)
        kv = c.kvs.get(oid) if c else None
        val = kv.get(key) if kv else None
        self.meter.record(self._target(oid), "kv_get",
                          len(val) if val else 0, unit=f"{label}/kv{oid}")
        return val

    def kv_remove(self, pool: str, label: str, oid: int, key: str) -> None:
        c = self.pools[pool].containers.get(label)
        kv = c.kvs.get(oid) if c else None
        if kv is not None:
            kv.entries.pop(key, None)
        self.meter.record(self._target(oid), "kv_put", 0,
                          unit=f"{label}/kv{oid}")

    def kv_list(self, pool: str, label: str, oid: int) -> List[str]:
        c = self.pools[pool].containers.get(label)
        kv = c.kvs.get(oid) if c else None
        keys = kv.keys() if kv else []
        self.meter.record(self._target(oid), "kv_list",
                          sum(len(k) for k in keys), unit=f"{label}/kv{oid}")
        return keys

    # -- array API --------------------------------------------------------------
    def array_open_with_attr(self, pool: str, label: str, oid: int,
                             oclass: str = "OC_S1") -> int:
        """No-RPC open/create (daos_array_open_with_attr, §3.1.1)."""
        c = self.pools[pool].containers[label]
        if oid not in c.arrays:
            with c.lock:
                c.arrays.setdefault(oid, _ArrayObject(oclass))
        return oid

    def array_write(self, pool: str, label: str, oid: int, offset: int,
                    data: bytes) -> None:
        c = self.pools[pool].containers[label]
        arr = c.arrays.get(oid)
        if arr is None:
            self.array_open_with_attr(pool, label, oid)
            arr = c.arrays[oid]
        arr.write(offset, data)
        stripes = self._stripes(arr.oclass)
        cell = max(1, (len(data) + stripes - 1) // stripes)
        for s in range(stripes):
            part = data[s * cell:(s + 1) * cell]
            if part:
                self.meter.record(self._target(oid, s), "array_write",
                                  len(part))
        replicas, parity = self._replicas(arr.oclass)
        for r in range(replicas):
            self.meter.record(self._target(oid, stripes + r), "repl_write",
                              len(data))
        if parity:
            self.meter.record(self._target(oid, stripes + replicas),
                              "repl_write", int(len(data) * parity))

    def array_read(self, pool: str, label: str, oid: int, offset: int,
                   length: int) -> bytes:
        c = self.pools[pool].containers[label]
        arr = c.arrays.get(oid)
        data = arr.read(offset, length) if arr else b""
        stripes = self._stripes(arr.oclass) if arr else 1
        cell = max(1, (len(data) + stripes - 1) // stripes)
        for s in range(stripes):
            part = data[s * cell:(s + 1) * cell]
            if part:
                self.meter.record(self._target(oid, s), "array_read",
                                  len(part))
        if not data:
            self.meter.record(self._target(oid), "array_read", 0)
        return data

    def array_get_size(self, pool: str, label: str, oid: int) -> int:
        c = self.pools[pool].containers[label]
        arr = c.arrays.get(oid)
        self.meter.record(self._target(oid), "kv_get", 8)
        return arr.size() if arr else 0

"""FDB-X core: the paper's domain-specific object store, in Python/JAX land.

Public surface:

>>> from repro.core import FDB, FDBConfig, Identifier
>>> fdb = FDB(FDBConfig(backend="daos", schema="nwp-object"))
>>> fdb.archive({...identifier...}, field_bytes)
>>> fdb.flush()
>>> data = fdb.retrieve({...identifier...}).read()
"""
from .faults import (FaultInjector, FaultSpec, InjectedCrash,
                     PermanentStorageError)
from .fdb import (FDB, FDBConfig, RecoveryReport, WriterSession,
                  as_identifier, reset_engines, shared_engine)
from .handle import (DataHandle, FieldLocation, FileRangeHandle, MultiHandle,
                     PlacementHandle, ShortReadError, group_mergeable)
from .interfaces import Catalogue, Store
from .lease import (Lease, LeaseConflictError, LeaseError, LeaseTable,
                    StaleLeaseError, set_lease_clock)
from .retry import (Deadline, DeadlineExceeded, RetryPolicy,
                    TransientStorageError, current_deadline, deadline_scope)
from .schema import (CHECKPOINT_SCHEMA, DATA_SCHEMA, Identifier,
                     NWP_OBJECT_SCHEMA, NWP_POSIX_SCHEMA, SCHEMAS, Schema,
                     TENSOR_SCHEMA)
from .engine.meter import GLOBAL_METER, Meter, client_context
from .engine.costmodel import PROFILES, HardwareProfile, model_run

__all__ = [
    "FDB", "FDBConfig", "WriterSession", "as_identifier", "reset_engines",
    "shared_engine",
    "DataHandle", "FieldLocation", "FileRangeHandle", "MultiHandle",
    "PlacementHandle", "ShortReadError", "group_mergeable",
    "Catalogue", "Store",
    "Lease", "LeaseTable", "LeaseError", "LeaseConflictError",
    "StaleLeaseError", "set_lease_clock",
    "FaultInjector", "FaultSpec", "InjectedCrash", "PermanentStorageError",
    "RecoveryReport",
    "RetryPolicy", "Deadline", "DeadlineExceeded", "TransientStorageError",
    "current_deadline", "deadline_scope",
    "Identifier", "Schema", "SCHEMAS",
    "NWP_OBJECT_SCHEMA", "NWP_POSIX_SCHEMA", "CHECKPOINT_SCHEMA",
    "DATA_SCHEMA", "TENSOR_SCHEMA",
    "GLOBAL_METER", "Meter", "client_context",
    "PROFILES", "HardwareProfile", "model_run",
]

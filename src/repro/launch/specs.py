"""input_specs(): ShapeDtypeStruct stand-ins (+ shardings) for every model
input of every (arch × shape) cell — no device allocation (thesis-style
dry-run probes)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeConfig
from repro.models import lm
from repro.models.config import ArchConfig
from repro.sharding.partition import (MeshPlan, make_param_shardings,
                                      shard_cache)
from repro.train.optimizer import adamw_init


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_shardings(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_tree, sharding_tree)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, plan: MeshPlan,
                param_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Training/prefill batch inputs."""
    mesh = plan.mesh
    B = shape.global_batch
    dp = plan.dp_axes if B % plan.dp_size == 0 else None
    S = shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.family == "audio":
        # enc-dec split: half the budget to stub frames, half to decoder
        s_dec, s_frames = S // 2, S // 2
        batch["tokens"] = _sds((B, s_dec), jnp.int32, mesh, P(dp, None))
        batch["labels"] = _sds((B, s_dec), jnp.int32, mesh, P(dp, None))
        batch["frames"] = _sds((B, s_frames, cfg.d_model), param_dtype, mesh,
                               P(dp, None, None))
    elif cfg.family == "vlm":
        s_text = S - cfg.n_patches
        batch["tokens"] = _sds((B, s_text), jnp.int32, mesh, P(dp, None))
        batch["labels"] = _sds((B, s_text), jnp.int32, mesh, P(dp, None))
        batch["patches"] = _sds((B, cfg.n_patches, cfg.d_model), param_dtype,
                                mesh, P(dp, None, None))
    else:
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, P(dp, None))
        batch["labels"] = _sds((B, S), jnp.int32, mesh, P(dp, None))
    return batch


def input_specs(cfg: ArchConfig, shape: ShapeConfig, plan: MeshPlan,
                param_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16
                ) -> Tuple[Tuple, Dict[str, Any]]:
    """Returns (args for the step function, info dict)."""
    mesh = plan.mesh
    pshard = make_param_shardings(cfg, plan)
    params = _with_shardings(lm.abstract_params(cfg, param_dtype), pshard)
    info: Dict[str, Any] = {"param_bytes_global": sum(
        a.size * a.dtype.itemsize for a in jax.tree.leaves(params))}

    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, params)
        opt_shardings = {
            "m": pshard, "v": pshard,
            "step": NamedSharding(mesh, P()),
        }
        opt = _with_shardings(opt_abs, opt_shardings)
        batch = batch_specs(cfg, shape, plan, param_dtype)
        return (params, opt, batch), info

    B = shape.global_batch
    src_len = 0
    if cfg.family == "audio":
        src_len = max(shape.seq_len // 4, 128)
    cache_len = shape.seq_len
    if cfg.family == "audio" and shape.kind == "prefill":
        cache_len = shape.seq_len // 2
    cache_abs = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, cache_len, cache_dtype, src_len))
    cache = _with_shardings(cache_abs, shard_cache(cfg, plan, cache_abs))
    info["cache_bytes_global"] = sum(
        a.size * a.dtype.itemsize for a in jax.tree.leaves(cache))

    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape, plan, param_dtype)
        batch.pop("labels", None)
        return (params, batch, cache), info

    # decode: one new token against the cache
    dp = plan.dp_axes if B % plan.dp_size == 0 else None
    token = _sds((B, 1), jnp.int32, mesh, P(dp, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return (params, token, cache, pos), info

"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (device count is locked on first jax init)."""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import (see launch/dryrun.py)")
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_local_mesh():
    """1×1 mesh over the single CPU device (smoke tests / examples)."""
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))

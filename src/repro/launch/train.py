"""Training driver.

Examples:
  # CPU-runnable reduced config, few hundred steps, FDB checkpoints:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 200 --batch 8 --seq 128 --backend daos

  # full config on real hardware (mesh picked up from the runtime):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --steps 1000
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core import FDBConfig
from repro.data import SyntheticTokens
from repro.train.checkpoint import FDBCheckpointer
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, run_with_restarts


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--backend", default="daos",
                   choices=["daos", "rados", "posix", "s3"])
    p.add_argument("--run", default="run0")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--async-ckpt", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    data = SyntheticTokens(cfg.vocab_size, args.seq, seed=args.seed)
    ck = FDBCheckpointer(args.run, FDBConfig(backend=args.backend),
                         asynchronous=args.async_ckpt)

    def batch_fn(step: int):
        b = data.batch(step, args.batch)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if cfg.family == "audio":
            out["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, args.seq // 2,
                                           cfg.d_model)) * 0.02
        if cfg.family == "vlm":
            out["patches"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, cfg.n_patches,
                                           cfg.d_model)) * 0.02
        return out

    def make():
        return Trainer(cfg, None, AdamWConfig(lr=args.lr), checkpointer=ck,
                       ckpt_every=args.ckpt_every, batch_fn=batch_fn,
                       seed=args.seed)

    trainer = run_with_restarts(make, args.steps)
    last = trainer.metrics[-1] if trainer.metrics else {}
    print(f"done: step={trainer.step} loss={last.get('loss'):.4f} "
          f"ckpts={ck.available_steps()}")
    ck.close()


if __name__ == "__main__":
    main()

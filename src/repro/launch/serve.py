"""Serving driver: batched decode over FDB-checkpointed weights.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
      --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import FDBConfig
from repro.models import lm
from repro.serve import Request, ServeEngine
from repro.train.checkpoint import FDBCheckpointer


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--run", default=None,
                   help="restore weights from this FDB checkpoint run")
    p.add_argument("--backend", default="daos")
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    if args.run:
        ck = FDBCheckpointer(args.run, FDBConfig(backend=args.backend))
        step, params = ck.restore_latest(params)
        print(f"restored weights from run {args.run} step {step}")

    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, plen,
                                               dtype=np.int32),
                           max_new_tokens=args.new_tokens))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s) stats={eng.stats}")


if __name__ == "__main__":
    main()

"""Elastic re-meshing: recompute the distribution plan after losing nodes.

At 1000+ node scale, pod-level failures must not kill the job: the
supervisor shrinks the data-parallel extent to the surviving slice, restores
from the latest FDB checkpoint (whose shards are replica-independent
objects), and continues with a rescaled global batch.  This module computes
the new mesh/plan and the shard reassignment; on real hardware the runtime
re-initialises jax.distributed with the survivor list.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.models.config import ArchConfig
from repro.sharding.partition import MeshPlan, make_plan


def shrink_mesh(mesh: Mesh, lost_data_rows: int) -> Mesh:
    """Drop ``lost_data_rows`` rows of the data axis (failed hosts)."""
    devs = mesh.devices
    axes = mesh.axis_names
    d_idx = axes.index("data")
    keep = devs.shape[d_idx] - lost_data_rows
    if keep < 1:
        raise RuntimeError("cannot shrink below one data row")
    slicer = [slice(None)] * devs.ndim
    slicer[d_idx] = slice(0, keep)
    return Mesh(devs[tuple(slicer)], axes)


def elastic_replan(cfg: ArchConfig, mesh: Mesh, lost_data_rows: int,
                   global_batch: int, kind: str = "train"
                   ) -> Tuple[MeshPlan, int]:
    """New plan + rescaled global batch after failures.

    Batch is scaled to keep per-device batch constant (optimizer LR should
    be rescaled by the caller if it keeps the original schedule)."""
    new_mesh = shrink_mesh(mesh, lost_data_rows)
    plan = make_plan(cfg, new_mesh, kind)
    old_dp = int(np.prod([mesh.shape[a] for a in plan.dp_axes]))
    new_dp = plan.dp_size
    new_batch = max(global_batch * new_dp // old_dp, new_dp)
    return plan, new_batch


def reassign_data_shards(n_shards: int, survivors: List[int]
                         ) -> Dict[int, List[int]]:
    """Deterministically spread orphaned data shards over survivors."""
    out: Dict[int, List[int]] = {s: [] for s in survivors}
    for shard in range(n_shards):
        out[survivors[shard % len(survivors)]].append(shard)
    return out

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the production mesh (16×16 single pod / 2×16×16 multi-pod),
  2. builds ShapeDtypeStruct inputs with full shardings (no allocation),
  3. ``jax.jit(step).lower(...).compile()`` — sharding bugs, unsupported
     collectives and compile-time OOMs surface here,
  4. records memory_analysis(), cost_analysis(), and per-type collective
     bytes parsed from the optimized (post-SPMD) HLO,
  5. applies the analytic while-loop FLOP corrections for scan-mode
     sequence recurrences (see EXPERIMENTS.md §Roofline — XLA cost analysis
     counts while bodies once),
  6. writes a JSON artifact consumed by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_artifacts
"""


import argparse
import json
import math
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_NAMES, get_config, eligible_shapes,
                           skip_reason, SHAPES)
from repro.configs.shapes import ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.config import ArchConfig
from repro.sharding.partition import MeshPlan, make_plan
from repro.train.steps import make_decode_step, make_prefill_step, \
    make_train_step

# TPU v5e-class hardware constants (per chip) — roofline denominators.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|"
                       r"u16|u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES.get(dtype.split("e")[0] if dtype.startswith("f8")
                          else dtype, 2)


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum operand bytes of every collective op in the (per-device,
    post-SPMD) HLO.  Returns {op: {"count": n, "operand_bytes": b}}."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        # lhs shape is the output; operands follow inside the parens
        paren = line[m.end():]
        operands = _SHAPE_RE.findall(paren)
        if operands:
            nbytes = sum(_shape_bytes(d, s) for d, s in operands)
        else:  # fall back to output size
            nbytes = _shape_bytes(*shapes[0])
        rec = out.setdefault(op, {"count": 0, "operand_bytes": 0.0})
        rec["count"] += 1
        rec["operand_bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# Analytic FLOP corrections for while-loop (scan) sequence recurrences
# ---------------------------------------------------------------------------

def loop_flop_correction(cfg: ArchConfig, shape: ShapeConfig,
                         plan: MeshPlan, mamba_chunk: int = 256) -> float:
    """Per-device FLOPs that XLA's cost analysis misses because they sit in
    while-loop bodies executed `trips` times but counted once.

    Applies to: mamba chunk loops when n_chunks > 32 (prefill_32k+),
    sLSTM per-timestep scans (always), for train (×3: fwd+bwd) and prefill
    (×1).  Decode steps have no sequence loops.  Estimates assume the inner
    (d_inner) dim is TP-sharded and tokens are DP-sharded.
    """
    if shape.kind == "decode":
        return 0.0
    mult = 3.0 if shape.kind == "train" else 1.0
    S = shape.seq_len
    if cfg.family == "audio":
        S = S // 2
    elif cfg.family == "vlm":
        pass
    B_local = max(shape.global_batch // plan.dp_size, 1)
    tp = plan.tp_size
    total = 0.0
    kinds = [cfg.block_pattern[i % len(cfg.block_pattern)]
             for i in range(cfg.n_layers)]

    UNROLL_LIMIT = 8                           # must match ssm.py/xlstm.py
    n_mamba = kinds.count("mamba")
    if n_mamba:
        n_chunks = S // min(mamba_chunk, S)
        if n_chunks > UNROLL_LIMIT:            # scan mode: body counted once
            di = cfg.d_inner // tp if cfg.d_inner % tp == 0 else cfg.d_inner
            N, R = cfg.ssm_state_dim, cfg.dt_rank
            per_tok = (2 * di * (R + 2 * N) + 2 * R * di
                       + di * N * (4 * math.log2(min(mamba_chunk, S)) + 8))
            missed = per_tok * S * B_local * (n_chunks - 1) / n_chunks
            total += missed * n_mamba * mult

    n_mlstm = kinds.count("mlstm")
    if n_mlstm:
        chunk = min(256, S)
        n_chunks = S // chunk
        if n_chunks > UNROLL_LIMIT:
            du = int(cfg.d_model * cfg.mlstm_proj_factor)
            du_l = du // tp if du % tp == 0 else du
            dk = du // cfg.n_heads
            per_tok = (6 * dk * dk            # blockwise qkv
                       + 4 * chunk * dk       # scores + weighted V
                       + 6 * dk)              # gates/normalizer
            missed = per_tok * du_l / dk * S * B_local \
                * (n_chunks - 1) / n_chunks / cfg.n_heads
            # simpler: per-token ≈ (qkv + intra-chunk quadratic) × heads
            per_tok2 = (6 * dk * dk + 4 * chunk * dk) * cfg.n_heads / tp
            missed = per_tok2 * S * B_local * (n_chunks - 1) / n_chunks
            total += missed * n_mlstm * mult

    n_slstm = kinds.count("slstm")
    if n_slstm:
        D = cfg.d_model
        dh = D // cfg.n_heads
        per_tok = 8 * D * D + 8 * D * dh      # W gates + blockdiag recurrence
        per_tok /= tp if D % tp == 0 else 1   # embed dim sharded via FSDP? no:
        # sLSTM W is sharded on embed (data) only under FSDP; compute is
        # replicated over model — keep unsharded estimate (conservative).
        per_tok = 8 * D * D + 8 * D * dh
        missed = per_tok * (S - 1) * B_local
        total += missed * n_slstm * mult
    return total


def estimate_tpu_peak(cfg: ArchConfig, shape: ShapeConfig, plan: MeshPlan,
                      arg_bytes_per_dev: int) -> Dict[str, float]:
    """Analytic per-device peak-HBM estimate for the TPU target.

    The CPU-backend ``memory_analysis()`` is recorded raw but overstates the
    TPU peak: XLA:CPU materialises fusible elementwise chains and does not
    reuse buffers across unrolled layers (measured ~6.8 GiB/layer where the
    fusion-reuse-correct working set is ~2 GiB — see EXPERIMENTS.md §Dry-run
    caveats).  This estimator composes: arguments (params/opt/cache, exact)
    + gradients + remat-saved layer boundaries + the largest single-layer
    transient + logits buffers.
    """
    tp, dp = plan.tp_size, plan.dp_size
    D, Vp = cfg.d_model, cfg.padded_vocab()
    B_l = max(shape.global_batch // dp, 1)
    S = shape.seq_len
    if cfg.family == "audio":
        S = S // 2
    S_l = S // tp if (plan.sp and S % tp == 0) else S
    bpe = 2  # bf16
    est: Dict[str, float] = {"arguments": float(arg_bytes_per_dev)}
    if shape.kind == "train":
        n_params_dev = cfg.param_count() * bpe / tp / (dp if plan.fsdp else 1)
        est["grads"] = n_params_dev
        est["remat_boundaries"] = cfg.n_layers * B_l * S_l * D * bpe
        # largest layer transient: attention scores (2× bf16 S×T buffers)
        kv, g = cfg.n_kv_heads, max(cfg.q_rep, 1)
        att = 2 * B_l * kv * g * S_l * S * bpe if "attn" in cfg.block_pattern \
            else 0
        mlp = 3 * B_l * S_l * max(cfg.d_ff, cfg.d_inner) * bpe / \
            max(tp if max(cfg.d_ff, cfg.d_inner) % tp == 0 else 1, 1)
        est["layer_transient"] = float(max(att, mlp))
        v_l = Vp // tp if Vp % tp == 0 else Vp
        est["logits"] = 2.0 * B_l * S_l * v_l * 4
    elif shape.kind == "prefill":
        kv = cfg.n_kv_heads
        g = max(cfg.q_rep, 1)
        S_loc = S // tp
        chunk = min(256, S_loc)
        est["layer_transient"] = float(
            2 * B_l * kv * g * chunk * S * bpe      # chunked scores+weights
            + 2 * B_l * S * kv * cfg.dh * bpe * 2)  # gathered K/V
        est["activations"] = float(B_l * S_loc * D * bpe * 4)
    else:
        est["decode_transient"] = float(
            4 * B_l * max(cfg.n_heads * cfg.dh, cfg.d_ff // max(tp, 1)) * bpe)
    est["total"] = float(sum(est.values()))
    # analytic HBM traffic (per step, per device): params/opt streams +
    # activation streams; the raw CPU "bytes accessed" counts every operand
    # of every unfused op and overstates TPU HBM traffic ~10×.
    if shape.kind == "train":
        opt_stream = 10.0 * est.get("grads", 0.0) * 2     # f32 m,v r/w + p
        act_stream = (est.get("remat_boundaries", 0.0) * 6      # fwd+bwd+remat
                      + est.get("layer_transient", 0.0) * 4 * cfg.n_layers
                      + est.get("logits", 0.0) * 3)
        est["hbm_traffic"] = float(est["arguments"] * 3 + opt_stream
                                   + act_stream)
    elif shape.kind == "prefill":
        est["hbm_traffic"] = float(
            est["arguments"] * 2
            + est.get("layer_transient", 0.0) * 2 * cfg.n_layers
            + est.get("activations", 0.0) * 2 * cfg.n_layers)
    else:
        est["hbm_traffic"] = float(est["arguments"] * 2)  # weights + cache
    return est


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             plan_overrides: Optional[Dict[str, Any]] = None,
             keep_hlo: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    plan = make_plan(cfg, mesh, shape.kind)
    if plan_overrides:
        import dataclasses as _dc
        plan = _dc.replace(plan, **plan_overrides)

    args, info = input_specs(cfg, shape, plan)
    if shape.kind == "train":
        step = make_train_step(cfg, plan)
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, plan, seq_len=shape.seq_len)
        donate = (2,)
    else:
        step = make_decode_step(cfg, plan)
        donate = (2,)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ca = dict(ca) if ca else {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    n_chips = int(math.prod(mesh.devices.shape))

    flops_dev = float(ca.get("flops", 0.0))
    correction = loop_flop_correction(cfg, shape, plan)
    flops_dev_corr = flops_dev + correction
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    coll_bytes_dev = sum(v["operand_bytes"] for v in colls.values())

    model_flops = model_flops_global(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "n_chips": n_chips,
        "plan": {"fsdp": plan.fsdp, "sp": plan.sp, "remat": plan.remat,
                 "dp_axes": list(plan.dp_axes)},
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "tpu_peak_estimate": estimate_tpu_peak(
            cfg, shape, plan, ma.argument_size_in_bytes),
        "cost": {
            "flops_per_device": flops_dev,
            "loop_correction_flops": correction,
            "flops_per_device_corrected": flops_dev_corr,
            "bytes_accessed_per_device": bytes_dev,
        },
        "collectives": colls,
        "collective_bytes_per_device": coll_bytes_dev,
        "roofline": {
            "compute_s": flops_dev_corr / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_bytes_dev / ICI_BW,
        },
        "roofline_adjusted": {
            "compute_s": flops_dev_corr / PEAK_FLOPS,
            "memory_s": 0.0,   # filled below from tpu_peak_estimate
            "collective_s": coll_bytes_dev / ICI_BW,
        },
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops / n_chips,
        "useful_flops_ratio": (model_flops / n_chips) / flops_dev_corr
        if flops_dev_corr else 0.0,
        "info": info,
    }
    result["roofline_adjusted"]["memory_s"] = \
        result["tpu_peak_estimate"]["hbm_traffic"] / HBM_BW
    r = result["roofline"]
    result["dominant_term"] = max(r, key=lambda k: r[k])
    ra = result["roofline_adjusted"]
    result["dominant_term_adjusted"] = max(ra, key=lambda k: ra[k])
    bound = max(ra.values())
    result["roofline_fraction"] = (
        (result["model_flops_per_device"] / PEAK_FLOPS) / bound
        if bound > 0 else 0.0)
    if keep_hlo:
        result["hlo_len"] = len(hlo)
    return result


def model_flops_global(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (fwd-only)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="dryrun_artifacts")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--optimize", default=None,
                   help="comma list of hillclimb levers: ffn=gather_weights,"
                        "moe_gather_seq,attn=tp_chunked,sp=off,fsdp=on,"
                        "attn_q_chunk=<n> (artifacts get an __opt-... tag)")
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)

    plan_overrides: Optional[Dict[str, Any]] = None
    opt_tag = ""
    if args.optimize:
        extra: Dict[str, Any] = {}
        plan_overrides = {}
        for item in args.optimize.split(","):
            if item == "moe_gather_seq":
                extra["moe_gather_seq"] = True
            elif item == "sp=off":
                plan_overrides["sp"] = False
            elif item == "sp=on":
                plan_overrides["sp"] = True
            elif item == "fsdp=off":
                plan_overrides["fsdp"] = False
            elif item == "fsdp=on":
                plan_overrides["fsdp"] = True
            elif "=" in item:
                k, v = item.split("=", 1)
                extra[k] = int(v) if v.isdigit() else v
        if extra:
            plan_overrides["extra"] = extra
        opt_tag = "__opt-" + args.optimize.replace("=", "").replace(",", "+")

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape_name in SHAPES:
                for mesh_kind in ("single", "multi"):
                    cells.append((arch, shape_name, mesh_kind))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, args.mesh)]

    for arch, shape_name, mesh_kind in cells:
        tag = (f"{arch}__{shape_name}__{mesh_kind}{opt_tag}").replace("/",
                                                                      "_")
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            res = run_cell(arch, shape_name, mesh_kind,
                           plan_overrides=plan_overrides)
        except Exception as e:  # noqa: BLE001 — record failures as data
            res = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = ""
        if status == "ok":
            mem = res["memory"]["peak_bytes_per_device"] / 2**30
            extra = (f" compile={res['compile_s']}s peak={mem:.2f}GiB/dev "
                     f"dominant={res['dominant_term']}")
        elif status == "error":
            extra = " " + res["error"][:160]
        print(f"[{status}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()

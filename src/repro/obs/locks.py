"""Named locks with an acquisition-order observer hook.

``NamedLock`` is a drop-in ``threading.Lock`` replacement that carries a
stable name and, *only when an observer is installed*, reports every
acquisition attempt together with the names of the locks the acquiring
thread already holds.  That is exactly the signal a lock-order recorder
needs to build the acquisition-order graph (``repro.analysis.protocol.
LockOrderRecorder``) and flag cycles — potential deadlocks — without any
runtime cost on the default path: with no observer the overhead is one
module-global read plus thread-local held-list bookkeeping.

Stdlib-only, like the rest of ``repro.obs``.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional, Tuple

#: observer signature: (names of locks already held by this thread,
#: name of the lock about to be acquired) — called BEFORE blocking on the
#: lock, so a recorder sees the ordering even if the acquire then waits.
Observer = Callable[[Tuple[str, ...], str], None]

_observer: Optional[Observer] = None
_held = threading.local()


def set_lock_observer(observer: Optional[Observer]) -> Optional[Observer]:
    """Install (or, with ``None``, remove) the process-wide acquisition
    observer; returns the previous one so callers can restore it."""
    global _observer
    prev = _observer
    _observer = observer
    return prev


def held_locks() -> Tuple[str, ...]:
    """Names of the :class:`NamedLock`\\ s the calling thread holds, in
    acquisition order (innermost last)."""
    return tuple(getattr(_held, "names", ()))


class NamedLock:
    """A ``threading.Lock`` with a name and an acquisition-order hook.

    Supports the full lock protocol (``acquire``/``release``/context
    manager, including ``acquire(blocking=False)``), so it substitutes for
    a plain lock anywhere — the FDB facade and the backends name their
    internal locks with it (``fdb.flush``, ``lease.table``,
    ``store.posix``, ...).
    """

    __slots__ = ("name", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        obs = _observer
        if obs is not None:
            obs(held_locks(), self.name)
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            names = getattr(_held, "names", None)
            if names is None:
                names = _held.names = []
            names.append(self.name)
        return ok

    def release(self) -> None:
        self._lock.release()
        names = getattr(_held, "names", None)
        if names and self.name in names:
            # remove the innermost occurrence (re-entrant naming is not,
            # but out-of-order release is, legal for plain locks)
            for i in range(len(names) - 1, -1, -1):
                if names[i] == self.name:
                    del names[i]
                    break

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        state = "locked" if self._lock.locked() else "unlocked"
        return f"NamedLock({self.name!r}, {state})"


__all__ = ["NamedLock", "set_lock_observer", "held_locks"]

"""Observability layer: structured tracing + metrics for the I/O stack.

Stdlib-only on purpose — every layer of the repo (backends, executor,
plans, facade) can import this package without creating a cycle or a
dependency.  See ``docs/observability.md`` for the span taxonomy and the
metric name registry.
"""
from .locks import NamedLock, held_locks, set_lock_observer
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      DEFAULT_LATENCY_BUCKETS_US)
from .trace import (GLOBAL_TRACER, PHASE_SPANS, Span, TraceBuffer, Tracer,
                    current_span, current_tracer, span)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS_US",
    "GLOBAL_TRACER", "PHASE_SPANS", "Span", "TraceBuffer", "Tracer",
    "current_span", "current_tracer", "span",
    "NamedLock", "held_locks", "set_lock_observer",
]

"""Lightweight structured tracing: spans, context propagation, exporters.

A :class:`Span` is one timed phase of the I/O stack — ``plan.resolve``,
``io.fetch``, ``codec.decode``, ``fdb.flush`` — with monotonic-clock
timestamps (``time.perf_counter_ns``), free-form attributes, and a parent
link.  The active span rides a :mod:`contextvars` ContextVar, so nesting
``with tracer.span(...)`` blocks builds the parent/child tree implicitly,
and because :class:`~repro.tensorstore.executor.ChunkExecutor` submits
work through ``contextvars.copy_context()``, spans opened inside worker
threads keep their caller's span as parent — a read plan's ``io.fetch``
spans land under its ``plan.execute`` even though they run on pool
threads.

Design points:

* **Near-zero cost when disabled.**  ``Tracer.span()`` on a disabled
  tracer returns a shared no-op context manager — one attribute check,
  no allocation, no clock read.  The instrumented hot paths stay within
  noise of the uninstrumented build.
* **Bounded buffer.**  Finished spans go into a ``TraceBuffer`` (a
  capacity-capped deque).  ``mark()``/``spans(since=...)`` give windowed
  access — the bench harness marks before each timed phase and pulls
  only that phase's spans.  Overflow evicts oldest and is counted, never
  raised.
* **Exporters, not a pipeline.**  ``chrome_trace()`` emits Chrome
  ``trace_event`` JSON (open in https://ui.perfetto.dev), ``rollup()`` a
  plain-text per-name table, ``phase_totals()`` the queue/io/decode/
  encode split the bench columns report.  All are pull-based; nothing
  runs unless asked.

This module is stdlib-only and imports nothing from ``repro`` except its
sibling :mod:`.metrics`, so any layer (backends, executor, kernels) can
import it without cycles.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextvars import ContextVar
from typing import Any, Dict, Iterable, List, Optional

from .metrics import MetricsRegistry

#: the active span for the current logical context (thread or copied
#: context inside an executor worker); None when not inside any span
_SPAN_VAR: ContextVar[Optional["Span"]] = ContextVar("repro_obs_span",
                                                     default=None)

#: span names that count toward each wall-time phase in
#: :meth:`Tracer.phase_totals`.  Exact names, not prefixes: nested spans
#: (``plan.execute`` around ``io.fetch``) must not double-count.
PHASE_SPANS: Dict[str, frozenset] = {
    "queue": frozenset({"executor.queue"}),
    "io": frozenset({"io.fetch", "io.archive"}),
    "decode": frozenset({"codec.decode"}),
    "encode": frozenset({"codec.encode"}),
}

DEFAULT_CAPACITY = 1 << 16


class Span:
    """One finished (or in-flight) timed phase.

    ``span_id``/``parent_id`` are tracer-local integers; ``parent_id`` is
    None for roots.  ``attrs`` is mutable while the span is open — callers
    set e.g. ``nbytes`` once known (``sp.attrs["nbytes"] = n``).
    """

    __slots__ = ("tracer", "name", "span_id", "parent_id", "thread_id",
                 "t0_ns", "t1_ns", "attrs")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: Optional[int], thread_id: int, t0_ns: int,
                 attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread_id = thread_id
        self.t0_ns = t0_ns
        self.t1_ns: Optional[int] = None
        self.attrs = attrs

    @property
    def duration_us(self) -> float:
        end = self.t1_ns if self.t1_ns is not None else time.perf_counter_ns()
        return (end - self.t0_ns) / 1_000.0

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "thread_id": self.thread_id,
                "t0_ns": self.t0_ns, "t1_ns": self.t1_ns,
                "duration_us": round(self.duration_us, 3),
                "attrs": dict(self.attrs)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, {self.duration_us:.1f}us)")


class _NoopSpan:
    """Shared do-nothing context manager — the disabled-tracing fast path.

    ``__enter__`` returns None, so instrumentation that annotates the
    span (``if sp is not None: sp.attrs[...] = ...``) skips cleanly.
    """

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb):
        return False


_NOOP = _NoopSpan()


class _SpanCM:
    """Context manager that opens a real span on a specific tracer."""

    __slots__ = ("_tracer", "_name", "_attrs", "_span", "_token")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> Span:
        tr = self._tracer
        parent = _SPAN_VAR.get()
        # a parent from a *different* tracer (two FDB clients with private
        # buffers in one context) would dangle — treat as root instead
        parent_id = (parent.span_id
                     if parent is not None and parent.tracer is tr else None)
        span = Span(tr, self._name, next(tr._ids), parent_id,
                    threading.get_ident(), time.perf_counter_ns(),
                    self._attrs)
        self._span = span
        self._token = _SPAN_VAR.set(span)
        return span

    def __exit__(self, exc_type, exc, tb):
        span = self._span
        span.t1_ns = time.perf_counter_ns()
        if exc_type is not None:
            span.attrs["error"] = exc_type.__name__
        _SPAN_VAR.reset(self._token)
        self._tracer._record(span)
        return False


class TraceBuffer:
    """Bounded in-memory store of finished spans.

    Append-only from the tracer's point of view; eviction (oldest first)
    happens silently at ``capacity`` and is reported via ``dropped``.
    ``total`` counts every span ever recorded, so ``mark()``/``since``
    windows remain valid across evictions.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self._total = 0
        self._lock = threading.Lock()

    def append(self, span: Span) -> None:
        with self._lock:
            self._buf.append(span)
            self._total += 1

    @property
    def total(self) -> int:
        return self._total

    @property
    def dropped(self) -> int:
        return self._total - len(self._buf)

    def window(self, since: int = 0) -> List[Span]:
        """Spans recorded at or after sequence number ``since`` (from
        :meth:`Tracer.mark`), oldest first."""
        with self._lock:
            buf = list(self._buf)
            total = self._total
        first_kept = total - len(buf)  # seq number of buf[0]
        skip = max(0, since - first_kept)
        return buf[skip:]

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._total = 0


class Tracer:
    """A trace buffer + metrics registry + span factory.

    One per FDB client by default (clients share :data:`GLOBAL_TRACER`
    unless given their own), mirroring how ``GLOBAL_METER`` works for
    byte/op accounting.  Disabled by default; ``enable()`` or construct
    with ``enabled=True``.
    """

    def __init__(self, enabled: bool = False,
                 capacity: int = DEFAULT_CAPACITY,
                 metrics: Optional[MetricsRegistry] = None):
        self.enabled = enabled
        self.buffer = TraceBuffer(capacity)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._ids = itertools.count(1)

    # -- control ------------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self.buffer.clear()
        self.metrics.clear()

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """Open a span: ``with tracer.span("io.fetch", backend="daos") as sp``.

        Returns the shared no-op when disabled (``sp`` is then None).
        """
        if not self.enabled:
            return _NOOP
        return _SpanCM(self, name, attrs)

    def record_complete(self, name: str, t0_ns: int, t1_ns: int,
                        parent: Optional[Span] = None,
                        **attrs: Any) -> Optional[Span]:
        """Record an already-measured interval (e.g. executor queue wait,
        where the start is on the submitting thread and the end on the
        worker).  ``parent`` is explicit because no ``with`` block wrapped
        the interval."""
        if not self.enabled:
            return None
        parent_id = (parent.span_id
                     if parent is not None and parent.tracer is self else None)
        span = Span(self, name, next(self._ids), parent_id,
                    threading.get_ident(), t0_ns, attrs)
        span.t1_ns = t1_ns
        self._record(span)
        return span

    def _record(self, span: Span) -> None:
        self.buffer.append(span)
        # backend store ops double as latency histograms — one place,
        # every backend, no per-backend metric plumbing
        if span.name.startswith("store."):
            self.metrics.histogram(span.name + "_us").observe(
                span.duration_us)

    # -- windowed access ----------------------------------------------------
    def mark(self) -> int:
        """Sequence number for ``since=`` windows: record, do work, then
        ``spans(since=mark)`` / ``phase_totals(since=mark)``."""
        return self.buffer.total

    def spans(self, since: int = 0) -> List[Span]:
        return self.buffer.window(since)

    @property
    def dropped(self) -> int:
        return self.buffer.dropped

    # -- exporters ----------------------------------------------------------
    def chrome_events(self, since: int = 0, pid: int = 0) -> List[Dict]:
        """Chrome ``trace_event`` "X" (complete) events for the window.

        Timestamps are perf-counter microseconds — consistent within a
        process, which is all Perfetto needs to lay out the timeline.
        """
        events = []
        for s in self.spans(since):
            events.append({
                "name": s.name, "ph": "X", "pid": pid, "tid": s.thread_id,
                "ts": s.t0_ns / 1_000.0,
                "dur": round(s.duration_us, 3),
                "args": {k: _jsonable(v) for k, v in s.attrs.items()},
            })
        return events

    def chrome_trace(self, since: int = 0, pid: int = 0,
                     process_name: str = "repro") -> Dict[str, Any]:
        """A complete, Perfetto-loadable trace document."""
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": process_name}}]
        return {"traceEvents": meta + self.chrome_events(since, pid),
                "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str, since: int = 0,
                           process_name: str = "repro") -> None:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(since, process_name=process_name), fh)

    def phase_totals(self, since: int = 0) -> Dict[str, float]:
        """Summed span time (µs) per wall-time phase: queue / io / decode /
        encode — the ``t_*`` bench columns.  Counts only the leaf span
        names in :data:`PHASE_SPANS`, so wrapping spans never double-count;
        concurrent spans sum, so totals can legitimately exceed wall time
        when the executor overlaps I/O."""
        totals = {phase: 0.0 for phase in PHASE_SPANS}
        for s in self.spans(since):
            for phase, names in PHASE_SPANS.items():
                if s.name in names:
                    totals[phase] += s.duration_us
        return {k: round(v, 3) for k, v in totals.items()}

    def rollup(self, since: int = 0) -> str:
        """Plain-text per-name table: count, total/mean/max µs."""
        agg: Dict[str, List[float]] = {}
        for s in self.spans(since):
            agg.setdefault(s.name, []).append(s.duration_us)
        if not agg:
            return "(no spans recorded)"
        name_w = max(len(n) for n in agg)
        lines = [f"{'span':<{name_w}}  {'count':>7} {'total_us':>12} "
                 f"{'mean_us':>10} {'max_us':>10}"]
        for name in sorted(agg):
            ds = agg[name]
            lines.append(f"{name:<{name_w}}  {len(ds):>7} {sum(ds):>12.1f} "
                         f"{sum(ds) / len(ds):>10.1f} {max(ds):>10.1f}")
        if self.dropped:
            lines.append(f"[trace buffer overflow: {self.dropped} oldest "
                         f"spans evicted]")
        return "\n".join(lines)


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# -- ambient helpers --------------------------------------------------------

def current_span() -> Optional[Span]:
    """The active span in this context, or None."""
    return _SPAN_VAR.get()


def current_tracer() -> Optional[Tracer]:
    """The tracer owning the active span, or None outside any span."""
    s = _SPAN_VAR.get()
    return s.tracer if s is not None else None


def span(name: str, **attrs: Any):
    """Ambient span: attach to whatever traced operation is in flight.

    Used by layers with no tracer handle of their own (the simulated
    backends, the executor) — if the caller is inside a traced span, the
    new span joins that tracer; otherwise this is the no-op fast path.
    """
    s = _SPAN_VAR.get()
    if s is None or not s.tracer.enabled:
        return _NOOP
    return _SpanCM(s.tracer, name, attrs)


#: process-wide default tracer, disabled out of the box — mirrors
#: ``GLOBAL_METER``.  ``benchmarks.run --trace`` enables it; FDB clients
#: use it unless constructed with a private tracer.
GLOBAL_TRACER = Tracer(enabled=False)


__all__ = ["Span", "Tracer", "TraceBuffer", "GLOBAL_TRACER", "PHASE_SPANS",
           "DEFAULT_CAPACITY", "span", "current_span", "current_tracer"]

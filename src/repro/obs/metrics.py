"""Dependency-free metrics primitives: counters, gauges, fixed-bucket
histograms, and the registry that names them.

The tracing layer (:mod:`.trace`) answers "where did *this* operation's
time go"; metrics answer the aggregate questions a trace buffer is the
wrong shape for — how many lease conflicts since the client opened, what
the executor's queue-depth high-water mark was, the latency distribution
of every DAOS archive op.  Everything here is stdlib-only and thread-safe
(one small lock per instrument), so the hot paths that record — the chunk
executor, the FDB facade, the I/O plans — pay a dict lookup and a locked
integer bump, nothing more.

Naming convention (dotted, lowercase): ``<layer>.<what>[_<unit>]`` —
``lease.conflicts``, ``executor.queue_us``, ``io.posix.fetch_us``,
``codec.bytes_decoded``.  The full taxonomy lives in
``docs/observability.md``.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Sequence, Tuple

#: default histogram bucket upper bounds in microseconds — roughly
#: logarithmic from "cached metadata hit" to "something is very wrong"
DEFAULT_LATENCY_BUCKETS_US: Tuple[float, ...] = (
    10, 25, 50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000,
    50_000, 100_000, 250_000, 1_000_000)


class Counter:
    """Monotonically increasing count (ops, bytes, conflicts)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self) -> Dict[str, object]:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Point-in-time level (queue depth, in-flight ops) with a high-water
    mark — ``max`` survives after the level drops back, which is what the
    bench columns want."""

    __slots__ = ("name", "_value", "_max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._max = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value
            if value > self._max:
                self._max = value

    def add(self, delta: float) -> None:
        with self._lock:
            self._value += delta
            if self._value > self._max:
                self._max = self._value

    @property
    def value(self) -> float:
        return self._value

    @property
    def max(self) -> float:
        return self._max

    def snapshot(self) -> Dict[str, object]:
        return {"type": "gauge", "value": self._value, "max": self._max}


class Histogram:
    """Fixed-bucket histogram (no deps, O(log buckets) observe).

    ``buckets`` are inclusive upper bounds; one implicit overflow bucket
    catches everything beyond the last bound.  Tracks count/sum/min/max
    exactly, so means stay honest even when the distribution saturates a
    bucket.
    """

    __slots__ = ("name", "bounds", "counts", "_count", "_sum", "_min",
                 "_max", "_lock")

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_US):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram buckets must be ascending, "
                             f"got {buckets!r}")
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(buckets)
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self.counts[i] += 1
            self._count += 1
            self._sum += value
            if self._min is None or value < self._min:
                self._min = value
            if self._max is None or value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        """Approximate p-quantile (0 < p <= 100): the upper bound of the
        bucket holding the p-th observation (the true max for the overflow
        bucket)."""
        with self._lock:
            count, counts = self._count, list(self.counts)
            hi = self._max
        if not count:
            return 0.0
        rank = max(1, int(round(p / 100.0 * count)))
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank:
                return self.bounds[i] if i < len(self.bounds) else (hi or 0.0)
        return hi or 0.0

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            buckets = {f"le_{b:g}": c
                       for b, c in zip(self.bounds, self.counts)}
            buckets[f"gt_{self.bounds[-1]:g}"] = self.counts[-1]
            return {"type": "histogram", "count": self._count,
                    "sum": round(self._sum, 3), "min": self._min,
                    "max": self._max,
                    "mean": round(self.mean, 3), "buckets": buckets}


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    One registry per :class:`~repro.obs.trace.Tracer` (and therefore per
    FDB client, or shared via the global tracer).  Asking for an existing
    name with a different instrument type raises — a name means one thing.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name, *args)
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        if buckets is None:
            return self._get(name, Histogram)
        return self._get(name, Histogram, buckets)

    def get(self, name: str):
        """The instrument registered under ``name``, or None."""
        return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Point-in-time dump of every instrument, keyed by name — what
        :meth:`repro.core.FDB.metrics` returns."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.snapshot() for name, m in sorted(items)}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_LATENCY_BUCKETS_US"]
